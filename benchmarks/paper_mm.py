"""Paper reproduction benchmarks on the 1024^3 MM workload:

  * fig1_fig15  — cost of the three oversimplifications (divisor-only 39%,
                  max-based model 9%, comm-pruning 45% in the paper).
  * table2      — design-space enumeration counts (18 MM / 30 CNN).
  * table3      — factorization-only vs hybrid mutation.
  * table4_fig5 — MP objectives Obj1/2/3 as seeds; MP-only gap (1.5x paper).
  * fig7_8_9    — search-quality/sample-efficiency/5s-budget comparison
                  across methods and all 18 designs.
  * fig10_table6— MM architecture study (ordering + dataflow conclusions).
"""

from __future__ import annotations

import time

from repro.core import (EvoConfig, GenomeSpace, PerformanceModel,
                        TilingProblem, U250, baselines, build_descriptor,
                        cnn_validation, enumerate_designs, evolve, matmul,
                        mm_1024, mp_solver, pruned_permutations, tune_design,
                        tune_workload)

from .common import emit, save_json, timed

_CFG = EvoConfig(epochs=120, population=64, seed=0)


def _best_design():
    wl = mm_1024()
    perm = [p for p in pruned_permutations(wl) if set(p.inner) == {"k"}][0]
    return wl, ("i", "j"), perm


def bench_fig1_fig15():
    wl, df, perm = _best_design()
    res, us = timed("odyssey", lambda: tune_design(wl, df, perm, cfg=_CFG),
                    warmup=0, repeats=1)
    model, space = res.model, GenomeSpace(wl, df)
    opt = res.latency_cycles

    space_d = GenomeSpace(wl, df, divisors_only=True)
    div = baselines.divisor_only_evolutionary(space_d, model, _CFG)
    r_div = opt / -model.fitness(div.best)

    mx = baselines.max_model_search(space, model, _CFG)
    r_max = opt / -model.fitness(mx.best)

    cp = baselines.comm_pruned_search(space, model, _CFG)
    r_comm = opt / -model.fitness(cp.best)

    emit("fig1_design1_divisor_only_ratio", us, f"{r_div:.3f} (paper 0.61)")
    emit("fig1_design2_max_model_ratio", us, f"{r_max:.3f} (paper 0.91)")
    emit("fig1_design3_comm_pruned_ratio", us, f"{r_comm:.3f} (paper 0.55)")
    emit("fig1_design4_odyssey_gflops", us, f"{res.throughput / 1e9:.0f}")
    save_json("fig1_fig15", {
        "odyssey_latency_cycles": opt,
        "odyssey_throughput_gflops": res.throughput / 1e9,
        "odyssey_dsp_frac": res.dsp / U250.dsp_available,
        "divisor_only_ratio": r_div, "max_model_ratio": r_max,
        "comm_pruned_ratio": r_comm,
        "paper": {"divisor_only": 0.61, "max_model": 0.91,
                  "comm_pruned": 0.55},
    })


def bench_table2():
    n_mm, us1 = timed("mm", lambda: len(enumerate_designs(mm_1024())),
                     warmup=0, repeats=1)
    n_cnn, us2 = timed("cnn", lambda: len(enumerate_designs(
        cnn_validation())), warmup=0, repeats=1)
    emit("table2_mm_designs", us1, f"{n_mm} (paper 18)")
    emit("table2_cnn_designs", us2, f"{n_cnn} (paper 30)")


def bench_table3():
    wl, df, perm = _best_design()
    desc = build_descriptor(wl, df, perm)
    model = PerformanceModel(desc, U250)

    space_d = GenomeSpace(wl, df, divisors_only=True)
    div, us1 = timed("fact", lambda: baselines.divisor_only_evolutionary(
        space_d, model, _CFG), warmup=0, repeats=1)
    space = GenomeSpace(wl, df)
    hyb, us2 = timed("hybrid", lambda: evolve(
        TilingProblem(space, model), _CFG), warmup=0, repeats=1)
    ratio = -hyb.best_fitness and (-div.best_fitness / -hyb.best_fitness)
    thr_ratio = (-div.best_fitness) / (-hyb.best_fitness)
    emit("table3_factorization_vs_hybrid", us1 + us2,
         f"throughput_ratio={1/thr_ratio:.3f} (paper 0.61)")
    g = hyb.best
    save_json("table3", {
        "factorization_cycles": -div.best_fitness,
        "hybrid_cycles": -hyb.best_fitness,
        "hybrid_tiling": g.as_dict(),
        "hybrid_uses_nondivisor": any(
            wl.loop(l).bound % g.t1(l) != 0 for l in wl.loop_names),
        "hybrid_dsp_frac": model.resources(g).dsp / U250.dsp_available,
    })


def bench_table4_fig5():
    wl, df, perm = _best_design()
    desc = build_descriptor(wl, df, perm)
    model = PerformanceModel(desc, U250)
    space = GenomeSpace(wl, df)
    full = tune_design(wl, df, perm, cfg=_CFG)
    out = {}
    for obj in ("obj1_comp", "obj2_comm", "obj3_comm_comp"):
        res, us = timed(obj, lambda o=obj: mp_solver.solve(
            space, model, o, starts=8, sweeps=6), warmup=0, repeats=1)
        lat = model.latency_cycles(res.genome)
        r = model.resources(res.genome)
        out[obj] = {"latency_x": lat / full.latency_cycles,
                    "dm_bytes": model.off_chip_bytes(res.genome),
                    "dsp": r.dsp, "feasible": res.feasible}
        emit(f"table4_mp_{obj}_latency_x", us,
             f"{lat / full.latency_cycles:.2f}")
        # fig5: seed evolution with this objective's solutions
        seeded = tune_design(wl, df, perm, cfg=EvoConfig(
            epochs=30, population=64, seed=0), mp_objective=obj)
        out[obj]["seeded_evo_cycles"] = seeded.latency_cycles
    unseeded = tune_design(wl, df, perm, cfg=EvoConfig(
        epochs=30, population=64, seed=0), use_mp_seed=False)
    out["no_solver_cycles"] = unseeded.latency_cycles
    out["odyssey_dm_vs_obj2_dm"] = (
        model.off_chip_bytes(full.evo.best)
        / max(1, out["obj2_comm"]["dm_bytes"]))
    emit("table4_odyssey_dm_x_more_than_min", 0,
         f"{out['odyssey_dm_vs_obj2_dm']:.1f} (paper 4.9)")
    save_json("table4_fig5", out)


def bench_fig7_8_9():
    """All 18 MM designs x {odyssey, random, SA, BO, pruned-exhaustive};
    plus the 5-second single-thread budget run (fig 9)."""
    wl = mm_1024()
    per_design = {}
    t0 = time.time()
    methods_best = {m: [] for m in
                    ("odyssey", "random", "sa", "bo", "exhaustive")}
    for df, perm in enumerate_designs(wl):
        desc = build_descriptor(wl, df, perm)
        model = PerformanceModel(desc, U250)
        space = GenomeSpace(wl, df)
        oe = tune_design(wl, df, perm, cfg=EvoConfig(
            epochs=60, population=48, seed=0))
        rnd = baselines.random_search(space, model, max_evals=2000, seed=0)
        # chains=1: the paper's SA is a single 2000-step anneal — the
        # lockstep-chains vectorization would change the schedule being
        # reproduced (the chains=1 batch path already skips the
        # object-overhead the figure should not measure)
        sa = baselines.simulated_annealing(space, model, max_evals=2000,
                                           seed=0)
        bo = baselines.bayesian_opt(space, model, max_evals=120, init=24,
                                    seed=0)
        ex = baselines.exhaustive_pruned(space, model, max_evals=4000,
                                         seed=0)
        best = min(oe.latency_cycles, -rnd.best_fitness, -sa.best_fitness,
                   -bo.best_fitness, -ex.best_fitness)
        lbl = f"[{','.join(df)}] {perm.label()}"
        per_design[lbl] = {
            "odyssey": best / oe.latency_cycles,
            "random": best / -rnd.best_fitness,
            "sa": best / -sa.best_fitness,
            "bo": best / -bo.best_fitness,
            "exhaustive": best / -ex.best_fitness,
        }
        for m in methods_best:
            methods_best[m].append(per_design[lbl][m])
    us = (time.time() - t0) * 1e6
    geo = {m: _geomean(v) for m, v in methods_best.items()}
    for m, g in sorted(geo.items(), key=lambda kv: -kv[1]):
        emit(f"fig7_{m}_frac_of_best", us / 5, f"{g:.3f}")
    wins = sum(1 for d in per_design.values()
               if d["odyssey"] >= max(d.values()) - 1e-9)
    emit("fig7_odyssey_wins_of_18", us / 5, f"{wins} (paper 13)")

    # fig9: 5-second whole-workload budget, single thread
    rep, us9 = timed("fig9", lambda: tune_workload(
        wl, cfg=EvoConfig(epochs=400, population=64, seed=0),
        time_budget_s=5.0), warmup=0, repeats=1)
    feas = [r for r in rep.results if r.feasible]
    frac = min(r.latency_cycles for r in feas) / \
        min(r.latency_cycles for r in rep.results)
    emit("fig9_5s_budget_frac_of_best", us9,
         f"{min(1.0, 1/frac if frac else 1):.3f} (paper >0.90)")
    save_json("fig7_8_9", {"per_design": per_design, "geomean": geo,
                           "wins": wins})


def _geomean(xs):
    import math
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))


def bench_fig10_table6():
    wl = mm_1024()
    rep = tune_workload(wl, cfg=EvoConfig(epochs=60, population=48, seed=0))
    rows = {}
    for r in rep.results:
        rows[r.design.label()] = {
            "throughput_gflops": r.throughput / 1e9,
            "dsp_frac": r.dsp / U250.dsp_available,
            "bram": r.bram, "feasible": r.feasible,
        }
    best = rep.best
    # paper conclusions: ordering <[i,j],k> dominates; dataflow [i,j] among
    # the top performers
    by_order = {}
    for r in rep.results:
        key = r.design.permutation.label()
        by_order.setdefault(key, []).append(r.throughput)
    order_geo = {k: _geomean(v) for k, v in by_order.items()}
    dominant = max(order_geo, key=order_geo.get)
    emit("fig10_dominant_ordering", 0, f"{dominant} (paper <[i,j],[k]>)")
    emit("fig10_best_design", 0, best.design.label())

    # table6: BRAM breakdown of the three orderings for dataflow [i]
    t6 = {}
    for r in rep.results:
        if r.design.dataflow == ("i",):
            g = r.evo.best
            res = r.model.resources(g)
            t6[r.design.permutation.label()] = {
                "latency_x": r.latency_cycles,
                "pes": r.descriptor.num_pes(g),
                "bram_breakdown": res.bram_breakdown,
            }
    base = min(v["latency_x"] for v in t6.values())
    for k in t6:
        t6[k]["latency_x"] = t6[k]["latency_x"] / base
    save_json("fig10_table6", {"designs": rows, "order_geomean": order_geo,
                               "table6_dataflow_i": t6})
