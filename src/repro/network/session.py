"""NetworkSession: whole-network DSE on top of the per-workload stack.

Flow (DESIGN.md §11):

  1. **Dedup** — the graph's shape classes (``LayerGraph.classes``): a
     32-layer model or a 13-layer CNN tunes each unique workload once.
  2. **Per-class sweeps** — one :class:`repro.core.SearchSession` per
     class, sharing the design registry: exact fingerprint hits return
     cached sweeps with zero evals (the serving pre-tune path), near
     misses transfer-seed the search.
  3. **Candidates** — each class winner is frozen into an
     :class:`~.assign.ArrayGeometry`; every (class, candidate) pair gets
     a fixed-geometry tiling re-tune (memoized).
  4. **Assignment** — exact DP (``assign.partition_dp``) solves the
     uniform (K=1) and heterogeneous (K>=2) layer->array partitions
     under the reconfiguration-cost model, and the session composes
     end-to-end network latency plus a (latency, DSP, BRAM) frontier.

``dataflow_study`` is the paper-parity path (Figs. 11/13/14): per-class
``tune_design`` under each dataflow with the ordering fixed to the
paper's ``<[o,h,w],[i,p,q]>``, expanded back to per-layer lists —
``benchmarks/paper_cnn.py`` delegates here.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import SearchSession, SessionConfig
from repro.core.evolutionary import EvoConfig
from repro.core.hardware import HardwareProfile, U250
from repro.core.design_space import enumerate_dataflows, pruned_permutations
from repro.core.tuner import TuneReport, tune_design

from .assign import (ArrayGeometry, AssignConfig, Assignment, TilingFit,
                     geometry_from_result, partition_dp, retune_tiling)
from .graph import ClassKey, LayerGraph


def geomean(xs: Sequence[float]) -> float:
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))


# ---------------------------------------------------------------------- #
# Paper-parity study: shared dataflow, per-layer tiling fully re-tuned
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class DataflowStudy:
    """Figs. 11/13/14 material: per-(dataflow, layer) best throughput."""

    table: Dict[str, List[float]]   # dataflow label -> per-layer throughput
    geomean: Dict[str, float]       # dataflow label -> geomean frac of peak
    best: str                       # dataflow with the highest geomean
    peak: List[float]               # per-layer peak across dataflows


def dataflow_study(graph: LayerGraph, cfg: Optional[EvoConfig] = None,
                   hw: HardwareProfile = U250,
                   inner: Sequence[str] = ("i", "p", "q")) -> DataflowStudy:
    """Single-dataflow loss vs per-layer peak, ordering fixed to
    ``<..., [inner]>`` (the paper's Fig. 13 setup).

    Tunes once per *shape class* and expands to per-layer lists, so the
    numbers are identical to the historical per-layer loop (duplicate
    layers always re-tuned to the same optimum) at a fraction of the
    evals.
    """
    cfg = cfg or EvoConfig()
    classes = graph.classes()
    wl0 = graph.nodes[0].wl
    dataflows = enumerate_dataflows(wl0)
    perm = [p for p in pruned_permutations(wl0)
            if set(p.inner) == set(inner)][0]

    per_class: Dict[Tuple[str, ClassKey], float] = {}
    for df in dataflows:
        for key, cls in classes.items():
            res = tune_design(cls.wl, df, perm, hw=hw, cfg=cfg)
            per_class[("+".join(df), key)] = res.throughput

    table: Dict[str, List[float]] = {}
    for df in dataflows:
        label = "+".join(df)
        row: List[float] = []
        for n in graph.nodes:
            row += [per_class[(label, n.key)]] * n.count
        table[label] = row
    n_layers = len(next(iter(table.values())))
    peak = [max(table[d][i] for d in table) for i in range(n_layers)]
    geo = {d: geomean([table[d][i] / peak[i] for i in range(n_layers)])
           for d in table}
    best = max(geo, key=geo.get)
    return DataflowStudy(table=table, geomean=geo, best=best, peak=peak)


# ---------------------------------------------------------------------- #
# Network report
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class NetworkParetoPoint:
    """One non-dominated deployment on the (latency, DSP, BRAM) frontier."""

    label: str
    latency_cycles: float
    dsp: int                        # largest array the fabric must host
    bram: int
    n_arrays: int


@dataclasses.dataclass
class NetworkReport:
    graph: Dict
    classes: Dict[str, Dict]        # class name -> summary
    candidates: List[str]           # candidate array labels
    per_layer_cycles: float         # sum of per-class optima (ideal)
    assignments: Dict[int, Dict]    # K -> assignment summary
    pareto: List[NetworkParetoPoint]
    total_evals: int                # evolutionary evals spent (0 if cached)

    @property
    def uniform_cycles(self) -> float:
        return self.assignments[1]["latency_cycles"]

    def recovered_frac(self, k: int) -> float:
        """Fraction of the uniform-vs-per-layer loss a K-array partition
        recovers (0 = none, 1 = reaches the per-layer ideal)."""
        uni = self.uniform_cycles
        gap = uni - self.per_layer_cycles
        if gap <= 0:
            return 1.0
        return (uni - self.assignments[k]["latency_cycles"]) / gap

    def as_json(self) -> Dict:
        return {
            "graph": self.graph,
            "classes": self.classes,
            "candidates": self.candidates,
            "per_layer_cycles": self.per_layer_cycles,
            "assignments": self.assignments,
            "pareto": [dataclasses.asdict(p) for p in self.pareto],
            "total_evals": self.total_evals,
        }


# ---------------------------------------------------------------------- #
# The session
# ---------------------------------------------------------------------- #
class NetworkSession:
    """Tune a whole :class:`LayerGraph` and solve its array assignment.

    >>> sess = NetworkSession(vgg16_graph(), registry=store)
    >>> report = sess.run(k_values=(1, 2, 4))
    >>> report.uniform_cycles / report.per_layer_cycles

    With a registry attached the per-class sweeps hit the persistent
    cache: a warm second run (same graph, same hardware) reports
    ``total_evals == 0``.
    """

    def __init__(self, graph: LayerGraph, hw: HardwareProfile = U250,
                 cfg: Optional[EvoConfig] = None,
                 registry=None,
                 session: Optional[SessionConfig] = None,
                 assign: Optional[AssignConfig] = None,
                 time_budget_s: Optional[float] = None):
        if len(graph) == 0:
            raise ValueError("empty LayerGraph")
        self.graph = graph
        self.hw = hw
        self.cfg = cfg or EvoConfig()
        self.registry = registry
        # serial by default: network sessions run inside benchmarks/CLIs
        # where the per-class sweep is already the parallel unit
        self.session = session or SessionConfig(executor="serial")
        self.assign = assign or AssignConfig()
        # wall-clock budget for the per-class sweeps, spent with the same
        # rollover rule as SearchSession's per-design slices: registry
        # hits and fast classes refund their share to the classes still
        # queued (a cached class costs ~0, so a warm NetworkSession gives
        # nearly the whole budget to the classes that actually search)
        self.time_budget_s = time_budget_s
        self._classes = graph.classes()
        self._reports: Dict[ClassKey, TuneReport] = {}
        self._fits: Dict[Tuple[ClassKey, int], TilingFit] = {}
        self._candidates: List[ArrayGeometry] = []

    # -- stage 1+2: per-class sweeps -----------------------------------
    def tune_classes(self) -> Dict[ClassKey, TuneReport]:
        import time as _time
        budget_left = self.time_budget_s
        todo = [k for k in self._classes if k not in self._reports]
        for n_left, key in zip(range(len(todo), 0, -1), todo):
            cls = self._classes[key]
            slice_s = None
            if budget_left is not None:
                slice_s = max(0.0, budget_left) / n_left
            t0 = _time.perf_counter()
            sess = SearchSession(cls.wl, hw=self.hw, cfg=self.cfg,
                                 registry=self.registry,
                                 time_budget_s=slice_s,
                                 session=self.session)
            self._reports[key] = sess.run()
            if budget_left is not None:
                # charge actual wall-clock: a cheap class (registry hit,
                # early abort) leaves its unused share in the pool
                budget_left -= _time.perf_counter() - t0
        return self._reports

    # -- stage 3: candidate arrays + cost matrix -----------------------
    def candidates(self) -> List[ArrayGeometry]:
        if self._candidates:
            return self._candidates
        self.tune_classes()
        seen = set()
        for key in self._classes:
            best = self._reports[key].best
            geom = geometry_from_result(best)
            tag = (geom.dataflow, geom.perm.order, geom.pe_dims, geom.simd)
            if tag not in seen:
                seen.add(tag)
                self._candidates.append(geom)
        return self._candidates

    def _fit(self, key: ClassKey, ci: int) -> TilingFit:
        memo_key = (key, ci)
        if memo_key not in self._fits:
            cls = self._classes[key]
            geom = self._candidates[ci]
            if not geom.compatible(cls.wl):
                raise ValueError(
                    f"candidate {geom.label()} incompatible with "
                    f"{cls.wl.name} (mixed-kind graph?)")
            # seed with this class's own tuned genome for the candidate's
            # design, when the sweep searched it
            seeds = [r.evo.best for r in self._reports[key].results
                     if tuple(r.design.dataflow) == geom.dataflow
                     and r.design.permutation.order == geom.perm.order]
            self._fits[memo_key] = retune_tiling(
                cls.wl, geom, hw=self.hw, evals=self.assign.retune_evals,
                seed=self.assign.seed, seeds=seeds[:2])
        return self._fits[memo_key]

    def cost_matrix(self) -> np.ndarray:
        """cost[l, c]: cycles of one execution of node l on candidate c
        (inf when the re-tuned schedule is infeasible on the fabric)."""
        cands = self.candidates()
        cost = np.full((len(self.graph), len(cands)), np.inf)
        for l, node in enumerate(self.graph.nodes):
            for ci in range(len(cands)):
                fit = self._fit(node.key, ci)
                if fit.feasible:
                    cost[l, ci] = fit.latency_cycles
        return cost

    # -- stage 4: assignment + composition -----------------------------
    def per_layer_cycles(self) -> float:
        """The ideal: every layer on its best candidate array with free
        reconfiguration — the lower bound every assignment is measured
        against (equals ``solve(len(graph))`` at zero reconfig cost).

        Computed from the same cost matrix the DP consumes, so it is a
        true bound even under tiny search budgets where a fixed-geometry
        re-tune can out-tune a class sweep's own winner."""
        cost = self.cost_matrix()
        counts = np.asarray([n.count for n in self.graph.nodes],
                            dtype=np.float64)
        return float((cost.min(axis=1) * counts).sum())

    def solve(self, k: int) -> Assignment:
        counts = [n.count for n in self.graph.nodes]
        return partition_dp(self.cost_matrix(), counts,
                            self.assign.effective_reconfig_cycles, k)

    def _assignment_resources(self, a: Assignment) -> Tuple[int, int]:
        dsp = bram = 0
        for l, node in enumerate(self.graph.nodes):
            fit = self._fit(node.key, a.choice[l])
            dsp = max(dsp, fit.dsp)
            bram = max(bram, fit.bram)
        return dsp, bram

    def _assignment_summary(self, a: Assignment) -> Dict:
        cands = self.candidates()
        dsp, bram = self._assignment_resources(a)
        return {
            "latency_cycles": a.latency_cycles,
            "compute_cycles": a.compute_cycles,
            "reconfig_cycles": a.reconfig_cycles,
            "n_arrays": a.n_arrays,
            "segments": [{"start": s, "end": e,
                          "array": cands[c].label()}
                         for s, e, c in a.segments],
            "dsp": dsp,
            "bram": bram,
        }

    def run(self, k_values: Sequence[int] = (1, 2, 4)) -> NetworkReport:
        self.tune_classes()
        per_layer = self.per_layer_cycles()
        k_values = sorted({max(1, k) for k in k_values})
        assignments: Dict[int, Dict] = {}
        points: List[NetworkParetoPoint] = []
        for k in k_values:
            a = self.solve(k)
            assignments[k] = self._assignment_summary(a)
            dsp, bram = self._assignment_resources(a)
            points.append(NetworkParetoPoint(
                label=f"K={k}", latency_cycles=a.latency_cycles,
                dsp=dsp, bram=bram, n_arrays=a.n_arrays))

        def dominated(p, q):
            le = (q.latency_cycles <= p.latency_cycles and q.dsp <= p.dsp
                  and q.bram <= p.bram)
            lt = (q.latency_cycles < p.latency_cycles or q.dsp < p.dsp
                  or q.bram < p.bram)
            return le and lt

        pareto = [p for p in points
                  if not any(dominated(p, q) for q in points if q is not p)]

        classes = {}
        total_evals = 0
        for key, cls in self._classes.items():
            rep = self._reports[key]
            evals = sum(r.evo.evals for r in rep.results)
            total_evals += evals
            best = rep.best
            classes[cls.wl.name] = {
                "count": cls.count,
                "best_design": best.design.label(),
                "latency_cycles": best.latency_cycles,
                "throughput_gflops": best.throughput / 1e9,
                "evals": evals,
                "from_cache": rep.from_cache,
            }
        return NetworkReport(
            graph=self.graph.summary(),
            classes=classes,
            candidates=[c.label() for c in self.candidates()],
            per_layer_cycles=per_layer,
            assignments=assignments,
            pareto=pareto,
            total_evals=total_evals,
        )


def report_to_json(report: NetworkReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.as_json(), f, indent=2, default=str)
