"""JAX-compiled evolution: the whole generation loop as one XLA program.

This is the third search engine (DESIGN.md §3).  The PR 5 NumPy SoA
engine made populations ``[B, L, 3]`` matrices but still runs ~10 NumPy
dispatches plus the scalar Mersenne draws per generation on the host;
here selection, crossover, mutation, legalization and fitness are all
array ops inside a single jitted ``lax.scan`` over generations, and a
``chains=`` axis is one extra ``vmap`` — multi-chain (island-model)
evolution and multi-chain SA cost barely more than one chain because the
whole run is a single dispatch.

RNG-stream mapping (documented contract — the point where this engine
*departs* from the NumPy oracle).  The SoA engine replays CPython's
Mersenne ``getrandbits`` stream draw-for-draw; that stream is inherently
sequential (rejection sampling consumes a data-dependent number of
draws), so a compiled engine cannot replicate it.  Instead each scalar
draw maps to a ``jax.random`` (threefry) draw of fixed shape:

  ============================  =====================================
  NumPy SoA draw                JAX draw
  ============================  =====================================
  selection coin rr()<rate      uniform[C] < rate
  parent pair sample(range(P))  j1=randint[C](0,P); j2=randint[C](0,P-1),
                                j2==j1 -> P-1   (CPython's k=2 pool trick:
                                uniform over distinct ordered pairs)
  per-loop coin rr()<0.5        uniform[C,L] < 0.5 (True -> first parent)
  mutation loop choice          randint[C](0,L)
  level pair sample(range(3),2) a=randint(0,3); b=randint(0,2), b==a -> 2
  hybrid coin rr()<alpha        uniform[C] < alpha (divisors_only: always)
  divisor choice(divs(va))      floor(uniform*nd) into a padded divisor
                                table (va with no divisor>1: f=1, a no-op,
                                like the scalar path's skipped mutation)
  random s=randint(1,va)        1 + floor(uniform*va)
  ============================  =====================================

Both streams are deterministic at a fixed seed, and the *search
distribution* is identical (every draw is uniform over the same set, up
to the <=2^-53 float-index bias of ``floor(u*n)``); only the realized
trajectories differ.  Equivalence to the oracle is therefore asserted at
the level that matters: on the reference searches both engines converge
to the same best genome and latency (``tests/test_batch_equivalence.py``),
and the fitness function itself matches to ``rtol=1e-12`` (``jax_model``).
Unlike the dedup'd NumPy engine, the compiled loop re-evaluates the full
population every generation (dedup is a host-side hash structure), so
``evals`` reports ``chains * population * (epochs_run + 1)`` — the count
actually computed.

Dtype policy: every entry point runs under ``jax.experimental.enable_x64``
(see ``jax_model``); genomes stay int64 end-to-end, divisions that must
round (tile counts, the random-mutation compensation ``ceil(va*vb/s)``)
go through float64 exactly like the NumPy legalizer.

Fork constraint: this module imports ``jax`` at module scope and must
only ever be imported lazily (``SoaHandle.jax_ops()`` /
``evolve(..., engine="jax")``) so ``core.engine``'s jax-free fork fast
path survives (``SearchSession._fork_safe``).
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from .design_space import (_divisors_gt1, _divisors_t, _pow2_floor,
                           _simd_opts, _snap_tables, genome_from_row,
                           genomes_to_matrix)
from .evolutionary import EvoConfig, EvoResult, TraceEntry
from .jax_model import build_fitness_fn
from repro.obs import get_tracer

__all__ = ["JaxEngineOps", "evolve_jax", "simulated_annealing_jax"]

_I8 = np.int64


def _pow2_floor_j(x):
    """jnp port of ``design_space._pow2_floor_arr`` (uint64 bit smear)."""
    x = x.astype(jnp.uint64)
    for s in (1, 2, 4, 8, 16, 32):
        x = x | (x >> s)
    return ((x >> 1) + 1).astype(jnp.int64)


def _fidx(u, n):
    """floor(u*n) clamped into [0, n-1] — the uniform-index draw."""
    return jnp.minimum((u * n).astype(jnp.int64), jnp.maximum(n - 1, 0))


class JaxEngineOps:
    """Compiled genome operators for one (space, batch model) pair.

    Everything data-independent — loop bounds, divisor tables, snap
    tables, the fitness pipeline's static structure — is baked into the
    traced functions as constants; compiled executables are cached per
    population/chain shape on this object (which ``SoaHandle.jax_ops()``
    in turn caches on the batch model), so repeated ``evolve`` calls at
    the same config pay zero retrace.
    """

    def __init__(self, space, batch_model, use_max_model: bool = False):
        self.space = space
        self.batch_model = batch_model
        self.use_max_model = bool(use_max_model)
        wl = space.wl
        self.names = list(wl.loop_names)
        self.L = len(self.names)
        self.div_only = bool(space.divisors_only)
        self.simd_max = wl.simd_max
        self.loops = []
        for l in wl.loops:
            is_simd = l.name == wl.simd_loop
            self.loops.append({
                "bound": l.bound,
                "lvl2": space.has_level2(l.name),
                "is_simd": is_simd,
                # the n2-alone-over-bound clamp value (static per loop)
                "shrunk": (min(_pow2_floor(max(1, l.bound)), wl.simd_max)
                           if is_simd else max(1, l.bound)),
                "snap": _snap_tables(l.bound) if self.div_only else None,
                "divs": np.asarray(_divisors_t(l.bound), dtype=_I8),
            })
        # global divisor tables over every level value that can occur
        # (legalized levels are <= max bound), padded with 1 so a value
        # without divisors > 1 turns the factorization move into a no-op
        maxb = max(lp["bound"] for lp in self.loops)
        gt1 = [_divisors_gt1(v) for v in range(maxb + 1)]
        alld = [_divisors_t(v) for v in range(maxb + 1)]
        self._nd_gt1 = np.array([len(d) for d in gt1], dtype=_I8)
        self._dt_gt1 = np.ones(
            (maxb + 1, max(1, max(len(d) for d in gt1))), dtype=_I8)
        for v, ds in enumerate(gt1):
            self._dt_gt1[v, :len(ds)] = ds
        self._nd_all = np.array([len(d) for d in alld], dtype=_I8)
        self._dt_all = np.ones(
            (maxb + 1, max(1, max(len(d) for d in alld))), dtype=_I8)
        for v, ds in enumerate(alld):
            self._dt_all[v, :len(ds)] = ds
        self._scnt = np.array(
            [len(_simd_opts(min(max(v, 1), wl.simd_max)))
             for v in range(maxb + 1)], dtype=_I8)
        self._fitness = build_fitness_fn(batch_model)
        self._compiled: dict = {}

    # -- traced pieces (must run inside jit under enable_x64) ------------
    def _fit_of(self, pop):
        return self._fitness(pop[:, :, 0], pop[:, :, 1], pop[:, :, 2],
                             self.use_max_model)

    def _legalize(self, mat):
        """jnp port of ``GenomeSpace.legalize_matrix`` (same op order)."""
        n0s, n1s, n2s = [], [], []
        for li, lp in enumerate(self.loops):
            bound = lp["bound"]
            n1 = jnp.maximum(1, mat[:, li, 1])
            n2 = jnp.maximum(1, mat[:, li, 2])
            if not lp["lvl2"]:
                n1 = n1 * n2
                n2 = jnp.ones_like(n2)
            if lp["is_simd"]:
                n2 = jnp.minimum(_pow2_floor_j(n2), self.simd_max)
            over = n1 * n2 > bound
            n1 = jnp.where(over, jnp.maximum(1, bound // n2), n1)
            over = n1 * n2 > bound
            n2 = jnp.where(over, lp["shrunk"], n2)
            n1 = jnp.where(over, 1, n1)
            if self.div_only:
                M, DI, T = (jnp.asarray(t) for t in lp["snap"])
                t1 = M[n1 * n2]
                n2 = T[DI[t1], jnp.minimum(n2, bound)]
                n1 = t1 // n2
            n0s.append(jnp.maximum(
                1, jnp.ceil(bound / (n1 * n2))).astype(jnp.int64))
            n1s.append(n1)
            n2s.append(n2)
        return jnp.stack([jnp.stack(n0s, 1), jnp.stack(n1s, 1),
                          jnp.stack(n2s, 1)], axis=2)

    def _sample(self, key, n: int):
        """jnp port of ``GenomeSpace.sample_matrix`` (same distribution)."""
        k1, k2 = jax.random.split(key)
        u1 = jax.random.uniform(k1, (n, self.L))
        u2 = jax.random.uniform(k2, (n, self.L))
        nd_all = jnp.asarray(self._nd_all)
        dt_all = jnp.asarray(self._dt_all)
        scnt = jnp.asarray(self._scnt)
        n1s, n2s = [], []
        for li, lp in enumerate(self.loops):
            bound = lp["bound"]
            if self.div_only:
                divs = jnp.asarray(lp["divs"])
                t1 = divs[_fidx(u1[:, li], len(lp["divs"]))]
            else:
                t1 = 1 + _fidx(u1[:, li], bound)     # randint(1, bound)
            if lp["lvl2"] and lp["is_simd"]:
                n2 = jnp.left_shift(jnp.asarray(1, jnp.int64),
                                    _fidx(u2[:, li], scnt[t1]))
                n1 = jnp.maximum(1, t1 // n2)
            elif lp["lvl2"]:
                n2 = dt_all[t1, _fidx(u2[:, li], nd_all[t1])]
                n1 = t1 // n2
            else:
                n1, n2 = t1, jnp.ones_like(t1)
            n1s.append(n1)
            n2s.append(n2)
        mat = jnp.stack([jnp.ones((n, self.L), jnp.int64),
                         jnp.stack(n1s, 1), jnp.stack(n2s, 1)], axis=2)
        return self._legalize(mat)

    def _mutate_rows(self, key, mat, alpha: float):
        """Raw hybrid mutation of every row (``soa_mutate_rows`` port)."""
        R = mat.shape[0]
        kli, ka, kb, kf, kfi, ks = jax.random.split(key, 6)
        rows = jnp.arange(R)
        li = jax.random.randint(kli, (R,), 0, self.L)
        a = jax.random.randint(ka, (R,), 0, 3)
        b = jax.random.randint(kb, (R,), 0, 2)
        b = jnp.where(b == a, 2, b)                 # sample(range(3), 2)
        if self.div_only:
            fact = jnp.ones((R,), bool)
        else:
            fact = jax.random.uniform(kf, (R,)) < alpha
        lv = mat[rows, li]                          # [R, 3]
        va = lv[rows, a]
        vb = lv[rows, b]
        nd = jnp.asarray(self._nd_gt1)[va]
        f = jnp.asarray(self._dt_gt1)[va, _fidx(
            jax.random.uniform(kfi, (R,)), nd)]     # 1 when nd == 0
        s = jnp.minimum(
            1 + (jax.random.uniform(ks, (R,)) * va).astype(jnp.int64), va)
        new_a = jnp.where(fact, va // f, s)
        new_b = jnp.where(fact, vb * f,
                          jnp.ceil(va * vb / s).astype(jnp.int64))
        return mat.at[rows, li, a].set(new_a).at[rows, li, b].set(new_b)

    # -- compiled entry points -------------------------------------------
    def get_runner(self, B: int, P: int, E: int, rate: float, alpha: float):
        """(prep, run) jitted pair for one evolve configuration.

        ``prep(keys[K], seed_mat)`` samples + scores the initial
        populations; ``run(keys, pop, fit, best_f, best_row, nsteps)``
        advances every chain ``nsteps`` generations in one dispatch and
        returns the updated state plus the per-epoch best-fitness trace.
        Both are vmapped over the leading chain axis.
        """
        cache_key = ("evo", B, P, E, rate, alpha)
        hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit
        C = B - E
        do_cross = rate > 0.0 and P >= 2

        def gen(key, pop, fit):
            order = jnp.argsort(-fit, stable=True)
            parents = pop[order[:P]]
            kc, kj1, kj2, kl, km = jax.random.split(key, 5)
            j1 = jax.random.randint(kj1, (C,), 0, P)
            if do_cross:
                cross = jax.random.uniform(kc, (C,)) < rate
                j2 = jax.random.randint(kj2, (C,), 0, max(P - 1, 1))
                j2 = jnp.where(j2 == j1, P - 1, j2)
                src = jnp.where(
                    cross[:, None],
                    jnp.where(jax.random.uniform(kl, (C, self.L)) < 0.5,
                              j1[:, None], j2[:, None]),
                    j1[:, None])
            else:
                src = jnp.broadcast_to(j1[:, None], (C, self.L))
            child = parents[src, jnp.arange(self.L)[None, :]]
            child = self._mutate_rows(km, child, alpha)
            pop = jnp.concatenate([pop[order[:E]], child]) if E else child
            pop = self._legalize(pop)
            return pop, self._fit_of(pop)

        def run(key, pop, fit, best_f, best_row, nsteps):
            def body(carry, _):
                key, pop, fit, best_f, best_row = carry
                key, sub = jax.random.split(key)
                pop, fit = gen(sub, pop, fit)
                i = jnp.argmax(fit)                 # first max, like the
                better = fit[i] > best_f            # stable argsort
                best_f = jnp.where(better, fit[i], best_f)
                best_row = jnp.where(better, pop[i], best_row)
                return (key, pop, fit, best_f, best_row), best_f
            carry = (key, pop, fit, best_f, best_row)
            carry, hist = lax.scan(body, carry, None, length=nsteps)
            return carry + (hist,)

        def prep(key, seed_mat):
            S = seed_mat.shape[0]
            if S >= B:
                pop = seed_mat[:B]
            elif S:
                pop = jnp.concatenate([seed_mat, self._sample(key, B - S)])
            else:
                pop = self._sample(key, B)
            fit = self._fit_of(pop)
            i = jnp.argmax(fit)
            return pop, fit, fit[i], pop[i]

        pair = (jax.jit(jax.vmap(prep, in_axes=(0, None))),
                jax.jit(jax.vmap(run, in_axes=(0, 0, 0, 0, 0, None)),
                        static_argnums=5))
        self._compiled[cache_key] = pair
        return pair

    def get_sa(self, R: int, temperature: float, steps: int, alpha: float):
        """Jitted lockstep-SA advance: ``sa(carry, step_idx[seg])``.

        The ``R`` chains are the batch axis of one state matrix — a
        16-chain step is the same single dispatch as a 1-chain step.
        Matches the NumPy lockstep SA except that the acceptance scale
        ``|best_f|`` is the *previous* step's global best (the NumPy loop
        updates it mid-step, chain by chain — a sequential dependence a
        compiled batch cannot have).
        """
        cache_key = ("sa", R, temperature, steps, alpha)
        hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit

        def step(carry, i):
            key, cur, cur_f, best_f, best_row = carry
            key, km, kacc = jax.random.split(key, 3)
            t = temperature * (1.0 - i / steps) + 1e-6
            cand = self._legalize(self._mutate_rows(km, cur, alpha))
            f = self._fit_of(cand)
            scale = jnp.abs(best_f) + 1e-9
            u = jax.random.uniform(kacc, (R,))
            accept = (f >= cur_f) | \
                (u < jnp.exp((f - cur_f) / scale / t * 1e3))
            cur = jnp.where(accept[:, None, None], cand, cur)
            cur_f = jnp.where(accept, f, cur_f)
            j = jnp.argmax(f)
            better = f[j] > best_f
            best_f = jnp.where(better, f[j], best_f)
            best_row = jnp.where(better, cand[j], best_row)
            return (key, cur, cur_f, best_f, best_row), best_f

        def sa_prep(key):
            cur = self._sample(key, R)
            cur_f = self._fit_of(cur)
            j = jnp.argmax(cur_f)
            return cur, cur_f, cur_f[j], cur[j]

        pair = (jax.jit(sa_prep),
                jax.jit(lambda carry, idx: lax.scan(step, carry, idx)))
        self._compiled[cache_key] = pair
        return pair


# ---------------------------------------------------------------------- #
# Engine drivers (host side)
# ---------------------------------------------------------------------- #
def evolve_jax(ops: JaxEngineOps, cfg: EvoConfig, seeds: Sequence = (),
               stop_fn=None, chains: int = 1) -> EvoResult:
    """``evolve`` through the compiled engine.

    ``chains`` independent populations run in lockstep under one vmap —
    an island model without migration; the result is the best across
    chains (first chain on ties).  ``seeds`` enter every chain's
    population (same rows, like the NumPy engine's seed injection).

    Dispatch is segmented only when it has to be: with a ``stop_fn`` the
    scan length is 1 (the callback is polled every epoch, same contract
    as the NumPy engine); with only a time budget, segments of up to 32
    epochs bound the overshoot; otherwise the whole run is one dispatch.
    """
    K = max(1, int(chains))
    B = cfg.population
    P = max(1, min(cfg.parents, B))
    E = min(cfg.elites, B - 1) if B > 1 else 0
    tr = get_tracer()
    # compile-vs-run provenance: a cold ops cache means the first prep +
    # first run dispatch pay the XLA compile (spans carry cold=True)
    cold = ("evo", B, P, E, cfg.crossover_rate,
            cfg.mutation_alpha) not in ops._compiled
    t0 = time.perf_counter()

    # deterministic eval accounting: every epoch evaluates K*B rows
    per_epoch = K * B
    epochs = cfg.epochs
    if cfg.max_evals is not None:
        done, budget_epochs = per_epoch, 0
        while budget_epochs < cfg.epochs and done < cfg.max_evals:
            budget_epochs += 1
            done += per_epoch
        epochs = budget_epochs

    if stop_fn is not None:
        seg_len = 1
    elif cfg.time_budget_s is not None:
        seg_len = min(32, max(1, epochs))
    else:
        seg_len = max(1, epochs)

    with enable_x64():
        prep, run = ops.get_runner(B, P, E, cfg.crossover_rate,
                                   cfg.mutation_alpha)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), K)
        seed_mat = (genomes_to_matrix(list(seeds)[:B], ops.names)
                    if seeds else np.zeros((0, ops.L, 3), dtype=_I8))
        with tr.span("evolve.jax.prep", cat="search", chains=K,
                     population=B, cold=cold):
            pop, fit, best_f, best_row = prep(keys, seed_mat)
            if tr.enabled:          # sync only when timing the span
                jax.block_until_ready(fit)
        evals = per_epoch
        trace: List[TraceEntry] = []

        def _best(bf) -> float:
            return float(jnp.max(bf))

        dt = time.perf_counter() - t0
        trace.append(TraceEntry(evals, dt, _best(best_f),
                                evals / max(1e-12, dt)))
        aborted = False
        epoch = 0
        while epoch < epochs:
            if cfg.time_budget_s is not None and \
                    time.perf_counter() - t0 >= cfg.time_budget_s:
                break
            if stop_fn is not None:
                k = int(jnp.argmax(best_f))
                g = genome_from_row(np.asarray(best_row)[k], ops.names)
                if stop_fn(epoch, _best(best_f), g):
                    aborted = True
                    break
            n = min(seg_len, epochs - epoch)
            with tr.span("evolve.jax.run", cat="search", epochs=n,
                         cold=cold and epoch == 0):
                keys, pop, fit, best_f, best_row, hist = run(
                    keys, pop, fit, best_f, best_row, n)
                # per-epoch trace from the scanned best-fitness history;
                # the wall clock is only observable at segment boundaries,
                # so all epochs of a segment share its end timestamp
                hist = np.asarray(hist)             # [K, n]
            dt = time.perf_counter() - t0
            for j in range(n):
                evals += per_epoch
                bf = float(hist[:, j].max())
                trace.append(TraceEntry(evals, dt, bf,
                                        evals / max(1e-12, dt)))
                if tr.enabled:
                    tr.counter("evolve.gen", best=bf, evals=evals,
                               evals_per_sec=evals / max(1e-12, dt))
            epoch += n

        k = int(jnp.argmax(best_f))
        best = genome_from_row(np.asarray(best_row)[k], ops.names)
        return EvoResult(best=best, best_fitness=_best(best_f),
                         evals=evals, seconds=time.perf_counter() - t0,
                         trace=trace, aborted=aborted)


def simulated_annealing_jax(ops: JaxEngineOps, max_evals: int = 3000,
                            temperature: float = 200.0, seed: int = 0,
                            time_budget_s: Optional[float] = None,
                            chains: int = 1, alpha: float = 0.4
                            ) -> EvoResult:
    """Multi-chain SA as one compiled scan (``baselines`` semantics:
    global eval budget across chains, same temperature schedule)."""
    R = max(1, min(chains, max_evals))
    steps = max(0, (max_evals - R) // R) if R > 1 else max_evals
    t0 = time.perf_counter()
    with enable_x64():
        sa_prep, sa_run = ops.get_sa(R, temperature, max(1, steps), alpha)
        key = jax.random.PRNGKey(seed)
        key, kinit = jax.random.split(key)
        cur, cur_f, best_f, best_row = sa_prep(kinit)
        carry = (key, cur, cur_f, best_f, best_row)
        evals = R
        trace: List[TraceEntry] = []
        seg_len = min(64, max(1, steps)) if time_budget_s else max(1, steps)
        i = 0
        while i < steps:
            if time_budget_s and time.perf_counter() - t0 >= time_budget_s:
                break
            n = min(seg_len, steps - i)
            carry, hist = sa_run(carry, jnp.arange(i, i + n))
            evals += n * R
            i += n
            trace.append(TraceEntry(evals, time.perf_counter() - t0,
                                    float(np.asarray(hist)[-1])))
        best_f, best_row = carry[3], carry[4]
        best = genome_from_row(np.asarray(best_row), ops.names)
        return EvoResult(best=best, best_fitness=float(best_f),
                         evals=evals, seconds=time.perf_counter() - t0,
                         trace=trace)
