"""Continuous batching: slot-based scheduling over a fixed-capacity KV cache.

The wave engine's barrier (every request waits for the slowest in its wave)
is the serving analog of the pruned design spaces the Odyssey paper
quantifies: convenient, but it idles compute slots on synchronization.
This engine removes it (DESIGN.md §10):

  * ``max_batch`` **decode slots** back a single batched cache of capacity
    ``max_seq`` per slot; a request occupies one slot from admission to its
    EOS/budget, then the slot is recycled for the next queued request
    mid-stream — no wave barrier;
  * **chunked prefill**: prompts enter the slot cache ``prefill_chunk``
    tokens per scheduler tick through the model's chunked decode step, so a
    long prompt never stalls decode of the other slots for more than one
    chunk;
  * the decode tick always runs the full slot batch; free/prefilling slots
    are *parked* — fed a dummy token with their write index pinned to the
    last cache row, which the cache-frontier contract
    (``layers.attn_decode``) makes invisible: a parked write is overwritten
    before any query can attend it.  Parked rows cost FLOPs, not
    correctness — the slot count trades that against admission latency;
  * per-request queue wait / TTFT / decode tok/s land in a
    :class:`repro.serve.ServeStats` report.

Mid-prefill slots keep their chunk cache aside and splice it into the
batched cache only when the prompt completes, so decode ticks in between
cannot pollute recurrent (SSM/conv) state; attention-family models prefill
through fixed-size padded chunks (one jit trace), recurrent families through
exact-length chunks (the SSD scan cannot mask padding out of its state).

The hot loop is one fused jit dispatch per tick (decode + argmax + position
advance, see ``EngineBase.decode_tick``) plus a single device->host sync
for the harvested tokens; slot splices and decode inputs are rebuilt only
when slot membership changes.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .engine import EngineBase
from .stats import Request, RequestMetrics, ServeStats
from repro import faults
from repro.obs import get_metrics, get_tracer


class _Slot:
    """Host-side bookkeeping for one decode slot."""

    def __init__(self, index: int):
        self.index = index
        self.state = "free"               # free | prefill | decode
        self.req: Optional[Request] = None
        self.req_idx = -1                 # input position of self.req
        self.pos = 0                      # cache rows written so far
        self.chunks: List[np.ndarray] = []  # pending prompt chunks
        self.cache: Optional[Dict] = None   # private cache while prefilling
        self.gen: List[int] = []
        self.admit_s = 0.0
        self.first_s = 0.0


class ContinuousServingEngine(EngineBase):
    """Slot scheduler: admit requests into free decode slots mid-stream."""

    scheduler = "continuous"

    def __init__(self, model, params, cfg, tuning=None, tune_evals: int = 800):
        super().__init__(model, params, cfg, tuning=tuning,
                         tune_evals=tune_evals)
        self._cache_dtype = jnp.float32 \
            if getattr(model.cfg, "dtype", "bfloat16") == "float32" \
            else jnp.bfloat16
        # padded fixed-size chunks need the attention cache-frontier
        # contract; recurrent state (SSM/conv) must see exact tokens only
        self._padded_chunks = model.supports_ragged
        self._chunk_fns: Dict[int, object] = {}
        # splice a one-slot cache into the batch cache (slot axis is 1 on
        # every leaf); the slot index is a traced arg — one compile total
        self._insert_fn = jax.jit(
            lambda cache, slot, s: {
                k: jax.lax.dynamic_update_slice_in_dim(cache[k], slot[k],
                                                       s, axis=1)
                for k in cache})

    # ------------------------------------------------------------------ #
    def _chunk_fn(self, C: int):
        """jit'd chunked prefill step for chunk length C: greedy next
        tokens (1, C) + updated slot cache (one trace per C; the padded
        path only ever uses C = cfg.prefill_chunk)."""
        if C not in self._chunk_fns:
            model = self.model

            def chunk(params, cache, tokens, pos):
                logits, cache = model.decode_step(params, cache, tokens, pos)
                return jnp.argmax(logits, -1).astype(jnp.int32), cache

            self._chunk_fns[C] = jax.jit(chunk)
        return self._chunk_fns[C]

    def _chunks_of(self, prompt: np.ndarray) -> List[np.ndarray]:
        C = self.cfg.prefill_chunk
        if not self._padded_chunks:
            return [prompt[i:i + C] for i in range(0, len(prompt), C)]
        out = []
        for i in range(0, len(prompt), C):
            part = prompt[i:i + C]
            if len(part) < C:  # pad to the fixed trace length; the pad rows
                part = np.pad(part, (0, C - len(part)))  # are never attended
            out.append(part)
        return out

    def _writes_needed(self, plen: int) -> int:
        C = self.cfg.prefill_chunk
        return ((plen + C - 1) // C) * C if self._padded_chunks else plen

    # ------------------------------------------------------------------ #
    def serve(self, requests: List[Request]
              ) -> Tuple[List[np.ndarray], ServeStats]:
        cfg = self.cfg
        S, T = cfg.max_batch, cfg.max_seq
        for r in requests:
            need = max(self._writes_needed(len(r.prompt)),
                       len(r.prompt) + r.max_new_tokens)
            if need > T:
                raise ValueError(
                    f"request needs {need} cache rows "
                    f"(prompt {len(r.prompt)} + {r.max_new_tokens} new) "
                    f"> max_seq={T}")
        t0 = time.perf_counter()
        tr = get_tracer()
        queue = self._sorted_queue(requests)
        cache = self.model.init_cache(S, T, dtype=self._cache_dtype)
        # every admission starts from this (immutable) empty one-slot cache
        fresh_slot = self.model.init_cache(1, T, dtype=self._cache_dtype)
        slots = [_Slot(s) for s in range(S)]
        outs: List[Optional[np.ndarray]] = [None] * len(requests)
        metrics: List[Tuple[int, RequestMetrics]] = []
        decode_steps = prefill_chunks = 0
        eos = cfg.eos_token

        # device-resident decode inputs: rebuilt from the host mirrors only
        # when slot membership changes (admission/finish), advanced inside
        # the fused tick between — the steady-state tick does a single D2H
        # transfer (the harvested tokens)
        kv0 = jnp.zeros((S,), jnp.int32)
        cur_host = np.zeros(S, np.int32)
        pos_host = np.full(S, T - 1, np.int32)   # parked rows: see module doc
        cur_dev = pos_dev = step_dev = None
        membership_dirty = True
        shed = timed_out = retried = 0

        # overload policy (DESIGN.md §15): deadlines + admission control
        # are policed once per tick; both paths account the request in
        # ``metrics`` exactly once, so nothing is ever silently dropped
        def _deadline(req: Request) -> Optional[float]:
            dl = req.deadline_s if req.deadline_s is not None \
                else cfg.deadline_s
            return None if dl is None else req.arrival_s + dl
        policed = cfg.deadline_s is not None \
            or cfg.admit_watermark is not None \
            or any(r.deadline_s is not None for r in requests)

        def drop(req_idx: int, req: Request, reason: str, now_s: float):
            """Account a request that never reached a slot (shed, or timed
            out while queued): empty output, zero tokens."""
            nonlocal shed, timed_out
            outs[req_idx] = np.zeros(0, np.int32)
            metrics.append((req_idx, RequestMetrics(
                request_id=req.request_id, prompt_len=len(req.prompt),
                new_tokens=0, queue_wait_s=now_s - req.arrival_s,
                ttft_s=0.0, decode_s=0.0, finish_reason=reason)))
            if reason == "shed":
                shed += 1
            else:
                timed_out += 1
            get_metrics().counter("serve." + reason)
            tr.instant("serve." + reason, cat="serve",
                       request_id=req.request_id,
                       queued_s=now_s - req.arrival_s)

        def police_queue(now_s: float):
            """Time out arrived requests past their deadline; shed the
            newest arrivals above the admission watermark."""
            kept: List = []
            waiting = 0
            while queue:
                idx, req = queue[0]
                if req.arrival_s > now_s:
                    break              # sorted by arrival: rest is future
                queue.popleft()
                dl = _deadline(req)
                if dl is not None and now_s > dl:
                    drop(idx, req, "timeout", now_s)
                elif cfg.admit_watermark is not None \
                        and waiting >= cfg.admit_watermark:
                    drop(idx, req, "shed", now_s)
                else:
                    kept.append((idx, req))
                    waiting += 1
            for item in reversed(kept):
                queue.appendleft(item)

        def finish(slot: _Slot, reason: str, now_s: float):
            nonlocal membership_dirty, timed_out
            req = slot.req
            outs[slot.req_idx] = np.array(slot.gen, np.int32)
            # a slot evicted mid-prefill has no first token: its TTFT and
            # decode time are undefined, reported as 0 and excluded from
            # ServeStats' TTFT aggregates (new_tokens == 0)
            started = bool(slot.gen)
            m = RequestMetrics(
                request_id=req.request_id, prompt_len=len(req.prompt),
                new_tokens=len(slot.gen),
                queue_wait_s=slot.admit_s - req.arrival_s,
                ttft_s=slot.first_s - req.arrival_s if started else 0.0,
                decode_s=now_s - slot.first_s if started else 0.0,
                finish_reason=reason)
            metrics.append((slot.req_idx, m))
            if reason == "timeout":
                timed_out += 1
                get_metrics().counter("serve.timeout")
            if tr.enabled:
                tr.instant("serve.finish", cat="serve",
                           request_id=req.request_id, slot=slot.index,
                           reason=reason, new_tokens=m.new_tokens)
                # rolling request-level latency series: render alongside
                # the slot-occupancy track for a live Perfetto view
                tr.counter("serve.request", ttft_ms=m.ttft_s * 1e3,
                           decode_tps=m.decode_tps)
            slot.state, slot.req, slot.gen = "free", None, []
            slot.chunks, slot.cache = [], None
            pos_host[slot.index] = T - 1
            membership_dirty = True

        while queue or any(s.state != "free" for s in slots):
            now = time.perf_counter() - t0
            if policed:
                police_queue(now)
                # deadline eviction of in-flight requests: a timed-out
                # slot frees immediately (partial output kept) so a
                # stuck/slow request can never wedge the slot forever
                for slot in slots:
                    if slot.state == "free":
                        continue
                    dl = _deadline(slot.req)
                    if dl is not None and now > dl:
                        finish(slot, "timeout", now)
            # --- admission: recycle free slots from the arrived queue --- #
            for slot in slots:
                if slot.state != "free" or not queue \
                        or queue[0][1].arrival_s > now:
                    continue
                slot.req_idx, slot.req = queue.popleft()
                slot.state = "prefill"
                slot.pos = 0
                slot.chunks = self._chunks_of(slot.req.prompt)
                slot.cache = fresh_slot
                slot.admit_s = now
                tr.instant("serve.admit", cat="serve",
                           request_id=slot.req.request_id, slot=slot.index,
                           queue_wait_ms=(now - slot.req.arrival_s) * 1e3)
            if tr.enabled:
                tr.counter("serve.slots",
                           decode=sum(1 for s in slots
                                      if s.state == "decode"),
                           prefill=sum(1 for s in slots
                                       if s.state == "prefill"),
                           free=sum(1 for s in slots if s.state == "free"))
                tr.counter("serve.queue_depth", depth=len(queue))
            if all(s.state == "free" for s in slots):
                # queue is non-empty but nothing has arrived yet
                time.sleep(max(0.0, queue[0][1].arrival_s
                               - (time.perf_counter() - t0)))
                continue

            # --- one prefill chunk per mid-prefill slot (keeps long --- #
            # --- prompts from stalling the decode of other slots)   --- #
            for slot in slots:
                if slot.state != "prefill":
                    continue
                chunk = slot.chunks.pop(0)
                fn = self._chunk_fn(len(chunk))
                with tr.span("serve.prefill_chunk", cat="serve",
                             slot=slot.index, tokens=len(chunk),
                             request_id=slot.req.request_id):
                    toks, slot.cache = fn(
                        self.params, slot.cache,
                        jnp.asarray(chunk[None, :].astype(np.int32)),
                        jnp.asarray([slot.pos], jnp.int32))
                    if tr.enabled:   # time the dispatch, not the queue
                        jax.block_until_ready(toks)
                slot.pos += len(chunk)
                prefill_chunks += 1
                if slot.chunks:
                    continue
                # prompt complete: splice the private cache into the batch
                # cache and take the first generated token from the last
                # real prompt row of this chunk
                plen = len(slot.req.prompt)
                # last *real* prompt row of this final chunk: padded chunks
                # have fixed length C, exact chunks end at their last row
                last_row = (plen - 1) % len(chunk) if self._padded_chunks \
                    else len(chunk) - 1
                first = int(np.asarray(toks)[0, last_row])
                cache = self._insert_fn(cache, slot.cache,
                                        jnp.int32(slot.index))
                slot.cache = None
                slot.pos = plen          # decode writes resume at plen
                slot.gen = [first]
                slot.first_s = time.perf_counter() - t0
                if eos is not None and first == eos:
                    finish(slot, "eos", slot.first_s)
                elif slot.req.max_new_tokens == 1:
                    finish(slot, "length", slot.first_s)
                else:
                    slot.state = "decode"
                    cur_host[slot.index] = first
                    pos_host[slot.index] = plen
                    membership_dirty = True

            # --- one fused decode tick over the full slot batch --- #
            if not any(s.state == "decode" for s in slots):
                continue
            if membership_dirty:
                cur_dev = jnp.asarray(cur_host[:, None])
                pos_dev = jnp.asarray(pos_host)
                step_host = np.array([1 if s.state == "decode" else 0
                                      for s in slots], np.int32)
                step_dev = jnp.asarray(step_host)
                membership_dirty = False
            # transient errors (device hiccup, injected TransientIOError)
            # retry the whole tick: its inputs are unchanged until the
            # assignment below succeeds, so a retry is exact
            last_exc: Optional[BaseException] = None
            for _ in range(max(1, cfg.tick_retries)):
                try:
                    faults.fault_point("serve.tick")
                    with tr.span("serve.decode_tick", cat="serve",
                                 active=int(sum(1 for s in slots
                                                if s.state == "decode"))
                                 if tr.enabled else 0):
                        nxt_cur, nxt_pos, nxt_cache = self.decode_tick(
                            self.params, cache, cur_dev, pos_dev, step_dev,
                            kv0)
                        # writable host mirror (np.asarray of a jax array
                        # is read-only); this D2H copy is the tick's one
                        # device sync, so the span brackets real work,
                        # not dispatch latency
                        nxt_host = np.array(nxt_cur)[:, 0]
                    cur_dev, pos_dev, cache = nxt_cur, nxt_pos, nxt_cache
                    cur_host = nxt_host
                    decode_steps += 1
                except OSError as exc:
                    last_exc = exc
                    retried += 1
                    get_metrics().counter("serve.tick_retries")
                    tr.instant("fault.tick_retry", cat="fault",
                               error=repr(exc))
                    continue
                break
            else:
                raise last_exc
            pos_host += step_host
            now_s = time.perf_counter() - t0
            for slot in slots:
                if slot.state != "decode":
                    continue
                tok = int(cur_host[slot.index])
                slot.gen.append(tok)
                slot.pos += 1
                if eos is not None and tok == eos:
                    finish(slot, "eos", now_s)
                elif len(slot.gen) >= slot.req.max_new_tokens:
                    finish(slot, "length", now_s)

        stats = ServeStats(scheduler=self.scheduler,
                           requests=[m for _, m in sorted(metrics)],
                           wall_s=time.perf_counter() - t0,
                           decode_steps=decode_steps,
                           prefill_chunks=prefill_chunks,
                           engine=type(self).__name__,
                           shed=shed, timed_out=timed_out, retried=retried)
        return outs, stats
