"""TuningService: synchronous lookups, background tuning (DESIGN.md §9).

The service is the front door serving replicas use: ``lookup`` answers
from an in-memory LRU (then disk) without ever blocking on search;
``get_or_tune`` adds the miss path — tune inline (``block=True``) or
hand the workload to a single background worker thread and return
``None`` so the caller can fall back to a default config now and pick
up the tuned one on a later call.

The worker runs sweeps with the *serial* executor by default: the
service may live inside a serving process whose threads make forked
pools unsafe, and background tuning is throughput, not latency, work.
"""

from __future__ import annotations

import collections
import logging
import queue
import threading
from typing import Dict, Optional

from repro.core.hardware import HardwareProfile, U250
from repro.core.workloads import Workload

from .fingerprint import workload_fingerprint
from .store import Record, RegistryStore
from .transfer import report_from_record
from repro import faults
from repro.obs import get_metrics, get_tracer

_log = logging.getLogger(__name__)


class TuningService:
    def __init__(self, store: Optional[RegistryStore] = None,
                 hw: HardwareProfile = U250,
                 lru_size: int = 128):
        # explicit identity check: RegistryStore has __len__, so an empty
        # store is falsy and `store or ...` would silently retarget the
        # default root
        self.store = store if store is not None else RegistryStore()
        self.hw = hw
        self.lru_size = lru_size
        self._lru: "collections.OrderedDict[str, Record]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self._queue: "queue.Queue" = queue.Queue()
        self._pending: set = set()
        self._worker: Optional[threading.Thread] = None
        self.stats: Dict[str, int] = collections.Counter()

    def _fp(self, wl: Workload, hw: Optional[HardwareProfile] = None,
            divisors_only: bool = False):
        variant = {"divisors_only": True} if divisors_only else None
        return workload_fingerprint(wl, hw or self.hw, variant=variant)

    # -- lookups --------------------------------------------------------
    def lookup(self, wl: Workload,
               hw: Optional[HardwareProfile] = None,
               divisors_only: bool = False) -> Optional[Record]:
        """Exact-hit record for ``wl``, or None.  Never tunes."""
        fp = self._fp(wl, hw, divisors_only)
        with self._lock:
            rec = self._lru.get(fp.digest)
            if rec is not None:
                self._lru.move_to_end(fp.digest)
                self.stats["lru_hits"] += 1
                get_metrics().counter("service.lru_hits")
                get_tracer().instant("service.lru_hit", cat="registry",
                                     workload=wl.name)
                return rec
        rec = self.store.get(fp)
        if rec is not None:
            self.stats["disk_hits"] += 1
            get_metrics().counter("service.disk_hits")
            get_tracer().instant("service.disk_hit", cat="registry",
                                 workload=wl.name)
            self.store.touch(fp)
            self._remember(rec)
        else:
            self.stats["misses"] += 1
            get_metrics().counter("service.misses")
            get_tracer().instant("service.miss", cat="registry",
                                 workload=wl.name)
        return rec

    def _remember(self, rec: Record) -> None:
        with self._lock:
            self._lru[rec.fingerprint] = rec
            self._lru.move_to_end(rec.fingerprint)
            while len(self._lru) > self.lru_size:
                self._lru.popitem(last=False)

    def invalidate(self, wl: Workload,
                   hw: Optional[HardwareProfile] = None,
                   divisors_only: bool = False) -> None:
        fp = self._fp(wl, hw, divisors_only)
        with self._lock:
            self._lru.pop(fp.digest, None)
        self.store.evict(fp)

    # -- miss path ------------------------------------------------------
    def get_or_tune(self, wl: Workload, cfg=None, block: bool = True,
                    **session_kwargs):
        """Cached ``TuneReport`` on a hit; tune on a miss.

        Hit: reconstructed report, ``from_cache=True``, zero evals.
        Miss + ``block``: runs the sweep inline (recording the result).
        Miss + ``not block``: schedules background tuning, returns None.
        """
        rec = self.lookup(
            wl, divisors_only=session_kwargs.get("divisors_only", False))
        if rec is not None:
            return report_from_record(rec, wl, self.hw)
        if not block:
            self.schedule(wl, cfg=cfg, **session_kwargs)
            return None
        return self._tune(wl, cfg, session_kwargs)

    def _tune(self, wl: Workload, cfg, session_kwargs):
        from repro.core.engine import SearchSession, SessionConfig
        faults.fault_point("service.tune")
        session_kwargs = dict(session_kwargs)
        session_kwargs.setdefault("session", SessionConfig(executor="serial"))
        with get_tracer().span("service.tune", cat="registry",
                               workload=wl.name):
            sess = SearchSession(wl, hw=self.hw, cfg=cfg,
                                 registry=self.store, **session_kwargs)
            report = sess.run()
        self.stats["tunes"] += 1
        get_metrics().counter("service.tunes")
        rec = self.store.get(self._fp(
            wl, divisors_only=session_kwargs.get("divisors_only", False)))
        if rec is not None:
            self._remember(rec)
        return report

    # -- background worker ----------------------------------------------
    def schedule(self, wl: Workload, cfg=None, **session_kwargs) -> bool:
        """Queue ``wl`` for background tuning; False if already pending."""
        fp = self._fp(wl, divisors_only=session_kwargs.get("divisors_only",
                                                           False))
        with self._lock:
            if fp.digest in self._pending:
                return False
            self._pending.add(fp.digest)
            # enqueue under the lock: the worker only exits after taking
            # the same lock and re-checking the queue is empty, so an
            # item is never stranded behind a worker that just timed out
            self._queue.put((fp.digest, wl, cfg, session_kwargs))
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._drain, name="tuning-service", daemon=True)
                self._worker.start()
        self.stats["scheduled"] += 1
        return True

    def _drain(self) -> None:
        while True:
            try:
                item = self._queue.get(timeout=0.5)
            except queue.Empty:
                with self._lock:
                    if self._queue.empty():
                        self._worker = None
                        return
                continue
            if item is None:            # close() wake-up, not work
                self._queue.task_done()
                continue
            digest, wl, cfg, session_kwargs = item
            try:
                self._tune(wl, cfg, session_kwargs)
            except Exception as exc:    # cache, not service: degrade, but
                # never silently — a poisoned workload must be visible in
                # logs and metrics, not just a mute counter (§15)
                self.stats["tune_errors"] += 1
                get_metrics().counter("registry.tune_failed")
                get_tracer().instant("registry.tune_failed", cat="registry",
                                     workload=wl.name, error=repr(exc))
                _log.warning("background tune of %r failed "
                             "(callers keep their fallback): %r",
                             wl.name, exc)
            finally:
                with self._lock:
                    self._pending.discard(digest)
                self._queue.task_done()

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Wait for queued background tunes; True if the queue drained."""
        deadline = threading.Event()
        t = threading.Thread(target=lambda: (self._queue.join(),
                                             deadline.set()), daemon=True)
        t.start()
        return deadline.wait(timeout)

    def close(self, timeout: float = 10.0) -> None:
        """Wait for in-flight work; the idle worker then exits on its own."""
        worker = self._worker
        if worker is not None and worker.is_alive():
            self._queue.put(None)       # wake a blocked get() promptly
            worker.join(timeout)
