"""Qwen3-14B [hf:Qwen/Qwen3-8B family] — dense, GQA, qk_norm."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936,
    mlp="silu_glu", qk_norm=True, rope_theta=1e6,
    train_microbatches=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, mlp="silu_glu", qk_norm=True,
    )
