"""The measurement ladder: genome -> timed kernel or deterministic estimate.

Every measurement is stamped with its provenance backend:

  * ``measured`` — the genome's block config run as a real Pallas kernel
    on an accelerator, warmup + best-of-N wall-clock;
  * ``interpret`` — the same kernel jit-compiled in Pallas interpret
    mode on CPU.  The interpreter is staged into XLA by ``jax.jit``, so
    after the (separately recorded) compile, per-call time is real work,
    not Python dispatch;
  * ``hlo_estimate`` — no timing at all: the kernel is lowered and
    compiled, the post-optimization HLO is costed by
    ``launch/hlo_costs.analyze`` (trip-count-aware flops + buffer
    bytes), and a roofline bound ``max(flops/peak, bytes/bw)`` is the
    estimate.  Fully deterministic, and still *genome-sensitive*: the
    HLO byte traffic varies with the block shape even when flops do
    not.  When jax itself is unavailable the same roofline is fed from
    an analytic tile-traffic model (``detail="analytic"``).

The ladder degrades in that order: a backend that cannot run here falls
to the next rung rather than failing — calibration must work on a
laptop CI runner and a TPU host alike, only the provenance differs.

jax is imported lazily inside functions only: ``repro.calib`` must stay
importable in fork-safe jax-free processes (see ``repro.analysis``'s
fork-safety rule).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import HardwareProfile
from repro.core.workloads import Workload
from repro.obs import get_metrics, get_tracer

from .timing import time_callable

BACKENDS = ("measured", "interpret", "hlo_estimate")


def workload_family(wl) -> str:
    """Human-readable workload family ("mm", "conv", ...).

    ``Fingerprint.family`` is a structural hash — right for cache keys,
    useless for a report row.  Correction factors group by this name
    prefix instead.
    """
    name = wl.name if isinstance(wl, Workload) else str(wl)
    for fam in ("mm", "conv"):
        if name == fam or name.startswith(fam + "_"):
            return fam
    return name.split("_", 1)[0] or name


def predicted_us(result, hw: HardwareProfile) -> float:
    """The analytical model's latency for a ``DesignResult``, in µs."""
    return float(result.latency_cycles) / hw.freq_hz * 1e6


@dataclasses.dataclass(frozen=True)
class MeasureConfig:
    """How the ladder measures one genome."""

    backend: str = "auto"          # "auto" | one of BACKENDS
    warmup: int = 1
    repeats: int = 3
    # timed interpret-mode runs are capped by problem size: above this
    # MAC count the interpreter (even staged) is too slow for a smoke
    # path, so the ladder drops to the hlo_estimate rung
    interpret_max_macs: int = 1 << 21
    # force the jax-free analytic cost path (tests, jax-less hosts)
    analytic_only: bool = False


@dataclasses.dataclass
class Measurement:
    """One measured-vs-predicted pair with provenance."""

    workload: str
    family: str
    hardware: str
    design: str                    # DesignPoint.label()
    genome: Dict[str, List[int]]
    predicted_us: float
    measured_us: float
    backend: str                   # provenance: one of BACKENDS
    rel_err: Optional[float] = None  # |measured - predicted| / measured
    compile_us: Optional[float] = None
    repeats: int = 1
    detail: str = ""
    measured_at: float = 0.0

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, payload: Dict) -> "Measurement":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in payload.items() if k in fields})


# ------------------------------------------------------------------ #
# Genome -> kernel config
# ------------------------------------------------------------------ #
def _mm_dims(wl: Workload) -> Tuple[int, int, int]:
    b = wl.bounds
    return int(b["i"]), int(b["j"]), int(b["k"])


def _mm_blocks(wl: Workload, genome) -> Tuple[int, int, int]:
    """The genome's array-partitioning tiles as Pallas block shape.

    ``T1 = n1 * n2`` per loop is the paper's array-partitioning tile —
    the exact analog of the BlockSpec block (DESIGN.md §2).  Clamped to
    the problem dims the way ``kernels.matmul`` itself clamps.
    """
    M, N, K = _mm_dims(wl)
    bm = max(1, min(int(genome.t1("i")), M))
    bn = max(1, min(int(genome.t1("j")), N))
    bk = max(1, min(int(genome.t1("k")), K))
    return bm, bk, bn


def _jax_platform() -> Optional[str]:
    try:
        import jax
        return jax.devices()[0].platform
    except Exception:  # repro: ignore[bare-except] -- jax missing or no backend: the measurement ladder degrades to the analytic rung by design
        return None


# ------------------------------------------------------------------ #
# Rungs
# ------------------------------------------------------------------ #
def _build_mm(wl: Workload, genome, interpret: bool):
    """(jitted fn, operands) for the genome's matmul kernel."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.matmul import MatmulConfig, matmul

    M, N, K = _mm_dims(wl)
    bm, bk, bn = _mm_blocks(wl, genome)
    cfg = MatmulConfig(bm=bm, bk=bk, bn=bn, k_innermost=True,
                       interpret=interpret)
    key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (M, K), dtype=jnp.float32)
    b = jax.random.normal(kb, (K, N), dtype=jnp.float32)
    fn = jax.jit(lambda x, y: matmul(x, y, config=cfg))
    return fn, (a, b), (bm, bk, bn)


def _timed_rung(wl: Workload, genome, cfg: MeasureConfig,
                interpret: bool) -> Tuple[float, float, str]:
    """(measured_us, compile_us, detail) from a real timed run."""
    tr = get_tracer()
    fn, (a, b), blocks = _build_mm(wl, genome, interpret)
    with tr.span("calib.compile", cat="calib", workload=wl.name,
                 interpret=interpret):
        t0 = time.perf_counter()
        fn(a, b).block_until_ready()
        compile_us = (time.perf_counter() - t0) * 1e6
    with tr.span("calib.run", cat="calib", workload=wl.name,
                 repeats=cfg.repeats):
        res = time_callable(lambda: fn(a, b),
                            warmup=max(0, cfg.warmup - 1),
                            repeats=cfg.repeats)
    return res.best_us, compile_us, "blocks=%dx%dx%d" % blocks


def _roofline_us(flops: float, byts: float, hw: HardwareProfile) -> float:
    # FPGA profiles have no flops_peak field — each DSP is one MAC/cycle
    peak = hw.flops_peak or 2.0 * hw.dsp_available * hw.freq_hz
    bw = hw.hbm_bw or hw.dram_bw
    compute_s = flops / peak if peak > 0 else 0.0
    memory_s = byts / bw if bw > 0 else 0.0
    return max(compute_s, memory_s) * 1e6


def _analytic_costs(wl: Workload, genome) -> Tuple[float, float]:
    """(flops, bytes) from the tile-traffic model — the jax-free rung.

    Byte traffic mirrors what the k-inner kernel's HLO shows: every
    (i, j, k) grid step streams one A block and one B block from HBM,
    and each output block is written once.
    """
    M, N, K = _mm_dims(wl)
    bm, bk, bn = _mm_blocks(wl, genome)
    gm = -(-M // bm)
    gn = -(-N // bn)
    gk = -(-K // bk)
    flops = 2.0 * M * N * K
    byts = 4.0 * (gm * gn * gk * (bm * bk + bk * bn) + M * N)
    return flops, byts


def _hlo_rung(wl: Workload, genome, hw: HardwareProfile,
              cfg: MeasureConfig) -> Tuple[float, float, str]:
    """(estimate_us, compile_us, detail) — deterministic, no timing."""
    if not cfg.analytic_only:
        try:
            from repro.launch.hlo_costs import analyze
            fn, (a, b), blocks = _build_mm(wl, genome, interpret=True)
            with get_tracer().span("calib.compile", cat="calib",
                                   workload=wl.name, hlo=True):
                t0 = time.perf_counter()
                hlo = fn.lower(a, b).compile().as_text()
                compile_us = (time.perf_counter() - t0) * 1e6
            costs = analyze(hlo)
            return (_roofline_us(costs.flops, costs.bytes, hw), compile_us,
                    "hlo blocks=%dx%dx%d flops=%g bytes=%g"
                    % (blocks + (costs.flops, costs.bytes)))
        except Exception:  # repro: ignore[bare-except] -- no jax / lowering failed: fall through to the analytic rung, the ladder's documented fallback
            pass
    flops, byts = _analytic_costs(wl, genome)
    return (_roofline_us(flops, byts, hw), 0.0,
            "analytic flops=%g bytes=%g" % (flops, byts))


def _resolve_backend(wl: Workload, cfg: MeasureConfig) -> str:
    """Pick the highest rung that can actually run here."""
    want = cfg.backend
    if want not in BACKENDS + ("auto",):
        raise ValueError(f"unknown backend {want!r}; "
                         f"expected one of {BACKENDS + ('auto',)}")
    fam = workload_family(wl)
    plat = None if cfg.analytic_only else _jax_platform()
    timable = fam == "mm" and plat is not None
    if want in ("auto", "measured") and timable and plat != "cpu":
        return "measured"
    if want == "measured" and timable:
        want = "interpret"         # no accelerator: degrade one rung
    if want in ("auto", "interpret") and timable and \
            wl.total_macs() <= cfg.interpret_max_macs:
        return "interpret"
    return "hlo_estimate"


# ------------------------------------------------------------------ #
# Entry points
# ------------------------------------------------------------------ #
def measure_result(wl: Workload, result, hw: HardwareProfile,
                   cfg: Optional[MeasureConfig] = None) -> Measurement:
    """Run the ladder for one ``DesignResult``'s best genome."""
    cfg = cfg or MeasureConfig()
    tr = get_tracer()
    genome = result.evo.best
    backend = _resolve_backend(wl, cfg)
    pred = predicted_us(result, hw)
    with tr.span("calib.measure", cat="calib", workload=wl.name,
                 design=result.design.label(), backend=backend):
        if backend == "measured":
            meas, compile_us, detail = _timed_rung(wl, genome, cfg,
                                                   interpret=False)
        elif backend == "interpret":
            meas, compile_us, detail = _timed_rung(wl, genome, cfg,
                                                   interpret=True)
        else:
            meas, compile_us, detail = _hlo_rung(wl, genome, hw, cfg)
    rel_err = abs(meas - pred) / meas if meas > 0 else None
    m = get_metrics()
    m.counter("calib.measurements")
    if rel_err is not None:
        m.observe("calib.rel_err", rel_err)
    return Measurement(
        workload=wl.name, family=workload_family(wl), hardware=hw.name,
        design=result.design.label(),
        genome={l: list(t) for l, t in genome.as_dict().items()},
        predicted_us=pred, measured_us=meas, backend=backend,
        rel_err=rel_err, compile_us=compile_us, repeats=cfg.repeats,
        detail=detail, measured_at=time.time())


def measure_top_k(wl: Workload, results: Sequence, hw: HardwareProfile,
                  cfg: Optional[MeasureConfig] = None) -> List[Measurement]:
    """Measure each result; emits the ``calibration`` counter track."""
    tr = get_tracer()
    out: List[Measurement] = []
    counts = {b: 0 for b in BACKENDS}
    for r in results:
        meas = measure_result(wl, r, hw, cfg)
        out.append(meas)
        counts[meas.backend] += 1
        if tr.enabled:
            tr.counter("calibration", **counts)
    return out
