"""Baseline search methods the paper compares against (its §5.2):

  * random search,
  * exhaustive search with DSP-utilization pruning (threshold 25%),
  * simulated annealing (T=200, hybrid-mutation step function — paper's setup),
  * Bayesian optimization (GP surrogate + expected improvement; our own
    numpy implementation, standing in for the fmfn/BayesianOptimization
    package which is unavailable offline),
  * divisor-only evolutionary search (factorization-based mutation only —
    paper Table 3 / Fig. 15),
  * communication-pruned search (Marvel-style: restrict to the minimal
    off-chip-traffic sub-space — paper Limitation 3),
  * max-based-model search (TENET-style latency model — paper Limitation 2).

All baselines share the fitness/eval budget accounting of the evolutionary
engine so sample-efficiency traces (paper Fig. 8) are comparable.
"""

from __future__ import annotations

import math
import random
import time
from typing import List, Optional, Tuple

import numpy as np

from .design_space import Genome, GenomeSpace, genome_from_row
from .evolutionary import EvoConfig, EvoResult, TilingProblem, TraceEntry, evolve
from .perf_model import BatchPerformanceModel, PerformanceModel


def _mk_result(best, best_f, evals, t0, trace) -> EvoResult:
    return EvoResult(best=best, best_fitness=best_f, evals=evals,
                     seconds=time.perf_counter() - t0, trace=trace)


def _batchable(model) -> Optional[BatchPerformanceModel]:
    """A batch evaluator when ``model`` is a plain scalar model.

    Exact type check on purpose: wrapped/proxy models (eval-counting test
    doubles, custom fitness shims) must keep the scalar loop so every one
    of their ``fitness`` calls still happens.
    """
    if type(model) is PerformanceModel:
        return BatchPerformanceModel(model.desc, model.hw)
    return None


# ---------------------------------------------------------------------- #
def random_search(space: GenomeSpace, model: PerformanceModel,
                  max_evals: int = 3000, seed: int = 0,
                  time_budget_s: Optional[float] = None,
                  chunk: int = 256) -> EvoResult:
    """Uniform sampling baseline.

    Plain ``PerformanceModel``s are evaluated in matrix chunks through the
    SoA pipeline (same RNG stream as the scalar loop, so the same winner
    at a fixed seed); the reported ``evals`` count stays the number of
    genomes actually evaluated — the Fig. 6/8 traces measure the
    algorithm, not Python object overhead.
    """
    rng = random.Random(seed)
    t0 = time.perf_counter()
    best, best_f = None, -math.inf
    trace: List[TraceEntry] = []
    evals = 0  # actual fitness evaluations: the time budget may break early
    batch_model = _batchable(model)
    if batch_model is not None:
        # under a deadline, sample in small chunks: the budget is checked
        # between chunks, so the overshoot is bounded by one chunk's
        # wall-clock (sub-ms at matrix speed, comparable to the scalar
        # loop's single-eval granularity)
        if time_budget_s:
            chunk = min(chunk, 64)
        while evals < max_evals:
            if time_budget_s and time.perf_counter() - t0 > time_budget_s:
                break
            n = min(chunk, max_evals - evals)
            mat = space.sample_matrix(rng, n)
            fit = batch_model.fitness_matrix(mat)
            evals += n
            j = int(np.argmax(fit))      # first occurrence, like the loop
            if fit[j] > best_f:
                best_f = float(fit[j])
                best = genome_from_row(mat[j], space.wl.loop_names)
            trace.append(TraceEntry(evals, time.perf_counter() - t0, best_f))
        return _mk_result(best, best_f, evals, t0, trace)
    for i in range(max_evals):
        if time_budget_s and time.perf_counter() - t0 > time_budget_s:
            break
        g = space.sample(rng)
        f = model.fitness(g)
        evals += 1
        if f > best_f:
            best, best_f = g, f
        if i % 50 == 0:
            trace.append(TraceEntry(i + 1, time.perf_counter() - t0, best_f))
    return _mk_result(best, best_f, evals, t0, trace)


# ---------------------------------------------------------------------- #
def exhaustive_pruned(space: GenomeSpace, model: PerformanceModel,
                      dsp_threshold: float = 0.25, max_evals: int = 200000,
                      seed: int = 0,
                      time_budget_s: Optional[float] = None) -> EvoResult:
    """Exhaustive sweep of the divisor sub-space, pruning designs below a DSP
    utilization threshold (the paper's §5.2 baseline)."""
    t0 = time.perf_counter()
    best, best_f = None, -math.inf
    trace: List[TraceEntry] = []
    evals = 0
    for g in space.enumerate_divisor_genomes(max_count=max_evals):
        if time_budget_s and time.perf_counter() - t0 > time_budget_s:
            break
        r = model.resources(g)
        if r.dsp < dsp_threshold * model.hw.dsp_available:
            continue  # pruned
        evals += 1
        f = model.fitness(g)
        if f > best_f:
            best, best_f = g, f
        if evals % 200 == 0:
            trace.append(TraceEntry(evals, time.perf_counter() - t0, best_f))
    if best is None:
        best = space.sample(random.Random(seed))
        best_f = model.fitness(best)
    return _mk_result(best, best_f, evals, t0, trace)


# ---------------------------------------------------------------------- #
def simulated_annealing(space: GenomeSpace, model: PerformanceModel,
                        max_evals: int = 3000, temperature: float = 200.0,
                        seed: int = 0,
                        time_budget_s: Optional[float] = None,
                        chains: int = 1) -> EvoResult:
    """SA with the hybrid mutation as the step function (paper's setup).

    ``chains > 1`` runs that many independent chains in lockstep on the
    SoA pipeline: each step mutates every chain's state (one scalar draw
    sequence per chain — the same stream a per-chain scalar SA would use)
    and evaluates all proposals in a single ``fitness_matrix`` call, so
    the Fig. 6 comparison measures annealing, not per-genome Python.  The
    eval budget is global across chains and ``evals`` reports exactly the
    evaluations performed.  ``chains=1`` on a plain model follows the
    identical trajectory as the historical scalar loop.
    """
    rng = random.Random(seed)
    t0 = time.perf_counter()
    batch_model = _batchable(model)
    if batch_model is not None:
        R = max(1, min(chains, max_evals))
        names = space.wl.loop_names
        cur_mat = space.sample_matrix(rng, R)
        cur_f = batch_model.fitness_matrix(cur_mat)
        evals = R
        jb = int(np.argmax(cur_f))
        best, best_f = genome_from_row(cur_mat[jb], names), float(cur_f[jb])
        trace: List[TraceEntry] = []
        # R=1 keeps the historical step count (trajectory parity with the
        # scalar loop); R>1 fits whole lockstep rounds into the budget
        steps = max(0, (max_evals - R) // R) if R > 1 else max_evals
        for i in range(steps):
            if time_budget_s and time.perf_counter() - t0 > time_budget_s:
                break
            t = temperature * (1.0 - i / steps) + 1e-6
            raw = space.soa_mutate_rows(cur_mat, rng, alpha=0.4)
            cand_mat = space.legalize_matrix(raw)
            f = batch_model.fitness_matrix(cand_mat)
            evals += R
            scale = abs(best_f) + 1e-9
            accept = np.zeros(R, dtype=bool)
            for r in range(R):
                fr, cr = float(f[r]), float(cur_f[r])
                # short-circuit order preserved: the acceptance coin is
                # drawn only for downhill moves, like the scalar loop
                if fr >= cr or rng.random() < math.exp(
                        (fr - cr) / scale / t * 1e3):
                    accept[r] = True
                if fr > best_f:
                    best_f = fr
                    best = genome_from_row(cand_mat[r], names)
            cur_mat = np.where(accept[:, None, None], cand_mat, cur_mat)
            cur_f = np.where(accept, f, cur_f)
            trace.append(TraceEntry(evals, time.perf_counter() - t0, best_f))
        return _mk_result(best, best_f, evals, t0, trace)
    cur = space.sample(rng)
    cur_f = model.fitness(cur)
    best, best_f = cur, cur_f
    trace: List[TraceEntry] = []
    evals = 1  # the initial sample; the time budget may break early
    for i in range(max_evals):
        if time_budget_s and time.perf_counter() - t0 > time_budget_s:
            break
        t = temperature * (1.0 - i / max_evals) + 1e-6
        cand = space.mutate(cur, rng, alpha=0.4)
        f = model.fitness(cand)
        evals += 1
        # fitness is -cycles; normalize the scale for the acceptance test
        scale = abs(best_f) + 1e-9
        if f >= cur_f or rng.random() < math.exp((f - cur_f) / scale / t * 1e3):
            cur, cur_f = cand, f
        if f > best_f:
            best, best_f = cand, f
        if i % 50 == 0:
            trace.append(TraceEntry(i + 1, time.perf_counter() - t0, best_f))
    return _mk_result(best, best_f, evals, t0, trace)


# ---------------------------------------------------------------------- #
def bayesian_opt(space: GenomeSpace, model: PerformanceModel,
                 max_evals: int = 300, init: int = 24, seed: int = 0,
                 time_budget_s: Optional[float] = None) -> EvoResult:
    """GP(RBF) + expected-improvement BO over log-tile features."""
    rng = random.Random(seed)
    t0 = time.perf_counter()

    def feats(g: Genome) -> np.ndarray:
        v = []
        for l in space.wl.loop_names:
            n0, n1, n2 = g.triples[l]
            v += [math.log(n0), math.log(n1), math.log(max(1, n2))]
        return np.array(v)

    X: List[np.ndarray] = []
    y: List[float] = []
    pts: List[Genome] = []
    best, best_f = None, -math.inf

    def observe(g: Genome):
        nonlocal best, best_f
        f = model.fitness(g)
        # log-compress: raw cycle counts span orders of magnitude
        X.append(feats(g))
        y.append(-math.log(max(1.0, -f)))
        pts.append(g)
        if f > best_f:
            best, best_f = g, f
        return f

    trace: List[TraceEntry] = []
    for _ in range(init):
        observe(space.sample(rng))

    n_iter = max_evals - init
    for i in range(n_iter):
        if time_budget_s and time.perf_counter() - t0 > time_budget_s:
            break
        Xa = np.stack(X)
        ya = np.array(y)
        mu_y, sd_y = ya.mean(), ya.std() + 1e-9
        yn = (ya - mu_y) / sd_y
        ls = math.sqrt(Xa.shape[1])
        d2 = ((Xa[:, None, :] - Xa[None, :, :]) ** 2).sum(-1)
        K = np.exp(-0.5 * d2 / ls ** 2) + 1e-6 * np.eye(len(Xa))
        try:
            L = np.linalg.cholesky(K)
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
        except np.linalg.LinAlgError:
            observe(space.sample(rng))
            continue
        # candidate pool: random samples + mutations of the incumbent
        cands = [space.sample(rng) for _ in range(128)]
        cands += [space.mutate(best, rng, 0.4) for _ in range(64)]
        Fc = np.stack([feats(g) for g in cands])
        d2c = ((Fc[:, None, :] - Xa[None, :, :]) ** 2).sum(-1)
        Kc = np.exp(-0.5 * d2c / ls ** 2)
        mu = Kc @ alpha
        v = np.linalg.solve(L, Kc.T)
        var = np.clip(1.0 - (v ** 2).sum(0), 1e-9, None)
        sd = np.sqrt(var)
        fbest = yn.max()
        z = (mu - fbest) / sd
        ei = sd * (z * _ncdf(z) + _npdf(z))
        observe(cands[int(np.argmax(ei))])
        if i % 10 == 0:
            trace.append(TraceEntry(len(y), time.perf_counter() - t0, best_f))
    return _mk_result(best, best_f, len(y), t0, trace)


def _ncdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def _npdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)


# ---------------------------------------------------------------------- #
def divisor_only_evolutionary(space_divisors: GenomeSpace,
                              model: PerformanceModel, cfg: EvoConfig
                              ) -> EvoResult:
    """Factorization-based mutation only => divisor tilings only
    (paper Table 3 first row / Fig. 15)."""
    cfg_d = EvoConfig(**{**cfg.__dict__, "mutation_alpha": 1.0})
    return evolve(TilingProblem(space_divisors, model), cfg_d)


def comm_pruned_search(space: GenomeSpace, model: PerformanceModel,
                       cfg: EvoConfig, slack: float = 1.001) -> EvoResult:
    """Marvel-style: first find the minimum off-chip traffic among feasible
    designs, then search only designs within ``slack`` of it (paper
    Limitation 3)."""
    from . import mp_solver
    res = mp_solver.solve(space, model, objective="obj2_comm",
                          starts=6, sweeps=6, seed=cfg.seed)

    # Tighten the minimum with a dedicated evolutionary DM minimization so
    # the pruning threshold is the true feasible minimum, as Marvel intends.
    def dm_fitness(g: Genome) -> float:
        f = -float(model.off_chip_bytes(g))
        r = model.resources(g)
        if not r.fits(model.hw):
            f *= 4.0
        return f

    dm_prob = TilingProblem(space, model, fitness_fn=dm_fitness)
    dm_res = evolve(dm_prob, EvoConfig(**{**cfg.__dict__}),
                    seeds=[res.genome])
    dm_min = min(model.off_chip_bytes(res.genome),
                 model.off_chip_bytes(dm_res.best))

    def fitness(g: Genome) -> float:
        f = model.fitness(g)
        if model.off_chip_bytes(g) > slack * dm_min:
            f -= abs(f) * 100.0  # outside the pruned sub-space
        return f

    problem = TilingProblem(space, model, fitness_fn=fitness)
    out = evolve(problem, cfg, seeds=[res.genome])
    out.best_fitness = model.fitness(out.best)  # report true fitness
    out.dm_min = dm_min  # the pruning threshold (bytes), for analyses
    return out


def max_model_search(space: GenomeSpace, model: PerformanceModel,
                     cfg: EvoConfig) -> EvoResult:
    """Search with the TENET-style max(compute, comm) latency model, then
    re-evaluate the winner with the accurate model (paper Limitation 2)."""
    res = evolve(TilingProblem(space, model, use_max_model=True), cfg)
    res.best_fitness = model.fitness(res.best)
    return res
